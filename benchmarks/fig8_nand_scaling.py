"""Fig. 8: 2FeFET-2T (NAND, precharge-free) search energy/latency scaling."""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.core import cam_array, energy


def run():
    for rows in (16, 32, 64, 128, 256):
        e = energy.search_energy_array("nand", rows, 32, 3)
        lat = energy.search_latency("nand", 32)
        cfg = cam_array.SEEMCAMConfig(bits=3, n_cells=32, n_rows=rows,
                                      variant="nand")
        arr = cam_array.SEEMCAMArray(cfg)
        key = jax.random.PRNGKey(rows)
        arr.program(jax.random.randint(key, (rows, 32), 0, 8))
        q = jax.random.randint(key, (16, 32), 0, 8)
        us = time_call(lambda qq: arr.search_batch(qq)[1], q)
        emit(f"fig8a_rows{rows}", us,
             f"energy_fj={e:.2f};latency_ps={lat:.1f}")

    for cells in (4, 8, 16, 32, 64):
        e = energy.search_energy_array("nand", 64, cells, 3)
        lat = energy.search_latency("nand", cells)
        emit(f"fig8b_cells{cells}", 0.0,
             f"energy_fj={e:.2f};latency_ps={lat:.1f};"
             f"e_per_bit_fj={energy.search_energy_per_bit('nand', cells, 3):.4f}")

    # precharge-free accounting: consecutive identical searches are free
    cfg = cam_array.SEEMCAMConfig(bits=3, n_cells=16, n_rows=8, variant="nand")
    arr = cam_array.SEEMCAMArray(cfg)
    key = jax.random.PRNGKey(0)
    arr.program(jax.random.randint(key, (8, 16), 0, 8))
    q = jax.random.randint(jax.random.fold_in(key, 1), (16,), 0, 8)
    arr.search(q)
    t1 = arr.transition_count
    arr.search(q)
    emit("fig8_derived", 0.0,
         f"repeat_search_transitions={arr.transition_count - t1};"
         f"nand_vs_nor_energy_ratio="
         f"{energy.nand_search_energy_word(32, 3) / energy.nor_search_energy_word(32, 3):.3f}")


if __name__ == "__main__":
    run()
