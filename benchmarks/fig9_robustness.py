"""Fig. 9: Monte-Carlo robustness under device variation (sigma = 54 mV).

Distributions of the matchline discharge current for the match case vs the
worst case (single adjacent-level mismatch), via the mibo_mc Pallas kernel.
Derived: sense-margin ratio and MC sensing-error rate across 3-bit words.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels.mibo_mc import ops as mc_ops

N_MC = 2048   # paper shows 100 transients; we push the same study further


def run():
    key = jax.random.PRNGKey(42)
    stored = jax.random.randint(key, (32,), 0, 8)

    us = time_call(
        lambda k: mc_ops.monte_carlo_ml_currents(k, stored, stored,
                                                 n_samples=N_MC), key,
        warmup=1, iters=3)

    i_match = mc_ops.monte_carlo_ml_currents(key, stored, stored,
                                             n_samples=N_MC)
    worst = stored.at[0].set((stored[0] + 1) % 8)
    i_mm = mc_ops.monte_carlo_ml_currents(key, stored, worst, n_samples=N_MC)

    from repro.core import mibo
    thr = mibo.I_D_THRESHOLD * 3  # TIQ SA trip point above the D threshold
    leak_rate = float(jnp.mean(i_match > thr))     # false discharge on match
    miss_rate = float(jnp.mean(i_mm < thr))        # missed worst-case mismatch
    emit("fig9_margin", us,
         f"n_mc={N_MC};sa_threshold_A={thr:.2e};"
         f"p1_mismatch_A={float(jnp.percentile(i_mm, 1)):.3e};"
         f"match_leak_rate={leak_rate:.5f};mismatch_miss_rate={miss_rate:.5f}")

    # per-bits margin scan: more bits -> tighter ladder -> smaller margin
    for bits in (1, 2, 3):
        st = jax.random.randint(key, (32,), 0, 1 << bits)
        wc = st.at[0].set((st[0] + 1) % (1 << bits))
        im = mc_ops.monte_carlo_ml_currents(key, st, st, bits=bits,
                                            n_samples=N_MC)
        ix = mc_ops.monte_carlo_ml_currents(key, st, wc, bits=bits,
                                            n_samples=N_MC)
        m = float(jnp.min(ix)) / max(float(jnp.max(im)), 1e-12)
        emit(f"fig9_bits{bits}", 0.0, f"min_margin_x={m:.1f}")


if __name__ == "__main__":
    run()
